"""RecurrentGemma / Griffin recurrent block: conv1d + RG-LRU gated recurrence.

RG-LRU:  r_t = sigmoid(W_a x_t + b_a)          recurrence gate
         i_t = sigmoid(W_x x_t + b_x)          input gate
         a_t = exp(-c * softplus(Lambda) * r_t)            (c = 8)
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is linear in h, so prefill/train use ``associative_scan``
(log-depth) — the Pallas ``linear_scan`` kernel implements the chunked TPU
version.  Decode carries (h, conv tail) as the layer's cache: constant-size
state is why recurrentgemma runs the long_500k cell.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.lm.config import LMConfig
from repro.models.lm.layers import init_linear, linear

_C = 8.0


def init_rglru_block(rng, cfg: LMConfig, dtype=jnp.float32):
    w = cfg.lru_width or cfg.d_model
    k1, k2, k3, k4, k5, k6 = jax.random.split(rng, 6)
    return {
        "in_x": init_linear(k1, cfg.d_model, w, dtype=dtype),
        "in_gate": init_linear(k2, cfg.d_model, w, dtype=dtype),
        "conv_w": (jax.random.normal(k3, (cfg.conv1d_width, w), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": init_linear(k4, w, w, dtype=dtype),
        "wx": init_linear(k5, w, w, dtype=dtype),
        # Lambda init so a^c in ~(0.9, 0.999) (Griffin appendix)
        "lam": jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32),
        "out": init_linear(k6, w, cfg.d_model, dtype=dtype),
    }


def _causal_conv1d(p, x):
    """Depthwise causal conv, width W.  x: [B, S, w]."""
    width = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * p["conv_w"][i].astype(x.dtype)
              for i in range(width))
    return out + p["conv_b"].astype(x.dtype)


def _gates(p, x):
    r = jax.nn.sigmoid(linear(p["wa"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(linear(p["wx"], x).astype(jnp.float32))
    decay = _C * jax.nn.softplus(p["lam"])  # [w], f32
    log_a = -decay * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) with a = exp(log_a); stable via expm1.
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = beta * i * x.astype(jnp.float32)
    return a, b


def rglru_scan(p, x, h0=None, *, use_assoc: bool = True, use_pallas: bool = False):
    """Linear recurrence over the sequence.  x: [B, S, w] -> (y, h_last)."""
    a, b = _gates(p, x)
    if use_pallas:
        from repro.kernels.linear_scan import linear_scan

        h0_ = jnp.zeros_like(a[:, 0]) if h0 is None else h0.astype(jnp.float32)
        h, h_last = linear_scan(a, b, h0_, use_pallas=True)
        return h.astype(x.dtype), h_last.astype(x.dtype)
    if use_assoc:
        if h0 is not None:
            # fold the carried state in as a virtual step 0
            a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
            b = jnp.concatenate([h0[:, None].astype(jnp.float32), b], axis=1)
        aa, hh = jax.lax.associative_scan(
            lambda l, r: (l[0] * r[0], l[1] * r[0] + r[1]), (a, b), axis=1)
        h = hh[:, 1:] if h0 is not None else hh
    else:
        def step(carry, ab):
            at, bt = ab
            h = carry * at + bt
            return h, h
        h0_ = jnp.zeros_like(a[:, 0]) if h0 is None else h0.astype(jnp.float32)
        _, h = jax.lax.scan(step, h0_, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
        h = jnp.moveaxis(h, 0, 1)
    return h.astype(x.dtype), h[:, -1].astype(x.dtype)


def rglru_block(p, cfg: LMConfig, x, *, cache=None):
    """Full Griffin recurrent block.  x: [B, S, d] -> (y, new_cache).

    cache = {"h": [B, w], "conv": [B, W-1, w]} or None (train/prefill from 0).
    """
    width = p["conv_w"].shape[0]
    gate = jax.nn.gelu(linear(p["in_gate"], x))
    u = linear(p["in_x"], x)
    use_pallas = getattr(cfg, "use_pallas_scan", False)
    if cache is not None:
        u_ext = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)
        conv = _causal_conv1d(p, u_ext)[:, width - 1 :]
        h_seq, h_last = rglru_scan(p, conv, h0=cache["h"], use_assoc=False,
                                   use_pallas=use_pallas)
        new_cache = {"h": h_last, "conv": u_ext[:, -(width - 1) :]}
    else:
        conv = _causal_conv1d(p, u)
        h_seq, h_last = rglru_scan(p, conv, use_pallas=use_pallas)
        new_cache = {"h": h_last, "conv": u[:, -(width - 1) :]}
    return linear(p["out"], h_seq * gate), new_cache


def init_rglru_cache(cfg: LMConfig, batch: int, dtype) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), dtype),
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
    }
