"""Shared LM layers: norms, embeddings, RoPE, MLP variants."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, gain, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * gain


def init_linear(rng, d_in, d_out, *, bias=False, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    p = {"w": (jax.random.normal(rng, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# ----------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, D], positions: [B, S] (absolute token positions)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------------ MLP
def init_mlp(rng, d_model, d_ff, kind: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(rng, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "wi": init_linear(k1, d_model, d_ff, dtype=dtype),
            "wg": init_linear(k2, d_model, d_ff, dtype=dtype),
            "wo": init_linear(k3, d_ff, d_model, dtype=dtype),
        }
    return {
        "wi": init_linear(k1, d_model, d_ff, dtype=dtype),
        "wo": init_linear(k3, d_ff, d_model, dtype=dtype),
    }


def mlp(p, x, kind: str):
    if kind == "swiglu":
        return linear(p["wo"], jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x))
    if kind == "geglu":
        return linear(p["wo"], jax.nn.gelu(linear(p["wg"], x)) * linear(p["wi"], x))
    if kind == "gelu":
        return linear(p["wo"], jax.nn.gelu(linear(p["wi"], x)))
    if kind == "relu_sq":
        return linear(p["wo"], jnp.square(jax.nn.relu(linear(p["wi"], x))))
    raise ValueError(kind)
