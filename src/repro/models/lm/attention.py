"""Attention variants: GQA/MQA full attention, blockwise (flash-style) online
softmax for long sequences, banded attention for sliding-window (SWA/local),
and single-step decode against a KV cache.

KV heads are never materialised ``G×`` — scores are computed grouped
([B, Hkv, G, Sq, Skv]) so MQA (granite kv=1) reads each KV element once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _grouped(q, n_kv):
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def full_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                   q_offset=0, kv_valid_from=0):
    """q: [B, Sq, H, D], k/v: [B, Skv, Hkv, D] -> [B, Sq, H, D].

    ``q_offset``: position of q[0] relative to k[0] (decode / banded chunks).
    ``kv_valid_from``: keys below this index are masked (padding).
    Materialises the [Sq, Skv] score matrix — use :func:`blockwise_attention`
    for long sequences.
    """
    b, sq, h, d = q.shape
    n_kv = k.shape[2]
    qg = _grouped(q, n_kv)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = kpos >= kv_valid_from
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, d)


def blockwise_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                        q_chunk: int = 512, kv_chunk: int = 512):
    """Flash-style online-softmax attention in pure JAX.

    Structure matters for BOTH directions of autodiff:
    - outer ``lax.map`` over q chunks, with ``jax.checkpoint`` on the chunk
      body: backward RECOMPUTES each chunk's score blocks instead of storing
      them (without this, autodiff stacks every kv-step's probs — measured
      8×20 GiB per layer on qwen train_4k);
    - inner ``lax.scan`` over kv chunks with online-softmax (m, l, acc)
      carry: peak live score block is [qc, kc], never [Sq, Skv].
    Causal q-chunks also skip kv blocks entirely above the diagonal via
    masking-free early bounds (the mask zeroes them; XLA DCEs full-block
    no-ops only with static bounds, so we keep the scan dense — acceptable:
    2× the minimal FLOPs on the strictly-lower triangle).
    """
    b, s, h, d = q.shape
    n_kv = k.shape[2]
    skv = k.shape[1]
    assert s % q_chunk == 0 and skv % kv_chunk == 0, (s, q_chunk, skv, kv_chunk)
    nq, nk = s // q_chunk, skv // kv_chunk
    g = h // n_kv

    kc_all = k.reshape(b, nk, kv_chunk, n_kv, d)
    vc_all = v.reshape(b, nk, kv_chunk, n_kv, d)
    scale = 1.0 / jnp.sqrt(jnp.float32(d))

    @jax.checkpoint
    def one_q_chunk(qi):
        qg = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, axis=1)
        qg = _grouped(qg, n_kv)  # [b, qc, kv, g, d]
        qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]  # [qc, 1]

        def kv_step(carry, inp):
            m, l, acc = carry  # [b,qc,kv,g], same, [b,qc,kv,g,d]
            ki, k_blk, v_blk = inp
            s_blk = jnp.einsum("bqhgd,bkhd->bqhgk", qg.astype(jnp.float32),
                               k_blk.astype(jnp.float32)) * scale
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= kpos > qpos - window
            mask5 = mask[None, :, None, None, :]  # [1,qc,1,1,kc]
            s_blk = jnp.where(mask5, s_blk, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
            # exp(NEG_INF - NEG_INF) would be 1 for fully-masked rows: zero them.
            p = jnp.where(mask5, jnp.exp(s_blk - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, v_blk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, q_chunk, n_kv, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, q_chunk, n_kv, g), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, n_kv, g, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc_all, 1, 0), jnp.moveaxis(vc_all, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, q_chunk, h, d).astype(q.dtype)

    out = jax.lax.map(one_q_chunk, jnp.arange(nq))  # [nq, b, qc, h, d]
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)


def banded_attention(q, k, v, *, window: int, q_chunk: int = 512):
    """Sliding-window attention with true sub-quadratic FLOPs.

    For each q chunk, only the ``window + q_chunk`` KV band is gathered
    (static shapes via dynamic_slice), so compute is O(S · window) — the
    long-context enabler for SWA archs (h2o-danube, recurrentgemma local attn).
    """
    b, s, h, d = q.shape
    assert s % q_chunk == 0, (s, q_chunk)
    nq = s // q_chunk
    band = window + q_chunk  # worst-case KV extent one q chunk can see
    kp = jnp.pad(k, ((0, 0), (band, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (band, 0), (0, 0), (0, 0)))

    def one_chunk(qi):
        qc = jax.lax.dynamic_slice_in_dim(q, qi * q_chunk, q_chunk, axis=1)
        # Band ends at the chunk's last position; padded coords shift by +band.
        kc = jax.lax.dynamic_slice_in_dim(kp, qi * q_chunk + q_chunk, band, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, qi * q_chunk + q_chunk, band, axis=1)
        # k index j is absolute position qi*q_chunk + q_chunk - band + j;
        # entries with absolute position < 0 are left-padding -> mask them.
        valid_from = band - q_chunk * (qi + 1)
        return full_attention(qc, kc, vc, causal=True, window=window,
                              q_offset=band - q_chunk, kv_valid_from=valid_from)

    out = jax.lax.map(one_chunk, jnp.arange(nq))  # [nq, B, qc, H, D]
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)


def decode_attention(q1, k_cache, v_cache, length, *, window: int | None = None):
    """One-token decode.  q1: [B, 1, H, D]; caches: [B, S_max, Hkv, D];
    ``length``: number of valid cache entries (the new token's position)."""
    b, _, h, d = q1.shape
    n_kv = k_cache.shape[2]
    qg = _grouped(q1, n_kv)[:, 0]  # [B, Hkv, G, D]
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) / jnp.sqrt(jnp.float32(d))
    kpos = jnp.arange(k_cache.shape[1])[None, :]
    length = jnp.asarray(length)
    length = length.reshape(-1, 1) if length.ndim else length[None, None]
    mask = kpos < length
    if window is not None:
        mask &= kpos >= length - window
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, d)
