"""Multi-head Latent Attention (DeepSeek-V2) — compressed KV cache.

Projections:  q = W_q x  -> per-head (nope ‖ rope) query
              [c_kv ‖ k_pe] = W_dkv x   (kv_lora_rank + rope_dim — the CACHE)
              k_nope, v = W_ukv · rmsnorm(c_kv)

Prefill/train decompress k, v and run standard attention.  Decode uses the
*absorbed* form: q_nope is folded through W_uk into the latent space, scores
are taken against the cached ``c_kv`` directly, and the value projection W_uv
is applied to the attended latent — so the per-token cache cost is
``kv_lora_rank + rope_dim`` (576) instead of ``2·H·D`` (4096 for 16 heads):
the paper-relevant memory saving that makes decode_32k × batch 128 fit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.attention import NEG_INF, blockwise_attention, full_attention
from repro.models.lm.config import LMConfig
from repro.models.lm.layers import apply_rope, init_linear, linear, rms_norm


def init_mla(rng, cfg: LMConfig, dtype=jnp.float32):
    m = cfg.mla
    h = cfg.n_heads
    kq, kd, ku, ko = jax.random.split(rng, 4)
    return {
        "wq": init_linear(kq, cfg.d_model, h * (m.qk_nope_head_dim + m.qk_rope_head_dim), dtype=dtype),
        "wdkv": init_linear(kd, cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim, dtype=dtype),
        "ckv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wukv": init_linear(ku, m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim), dtype=dtype),
        "wo": init_linear(ko, h * m.v_head_dim, cfg.d_model, dtype=dtype),
    }


def _project_q(p, cfg: LMConfig, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    q = linear(p["wq"], x).reshape(b, s, cfg.n_heads, m.qk_nope_head_dim + m.qk_rope_head_dim)
    qn, qr = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    qr = apply_rope(qr, positions, cfg.rope_theta)
    return qn, qr


def _project_ckv(p, cfg: LMConfig, x, positions):
    m = cfg.mla
    ckv_full = linear(p["wdkv"], x)
    c_kv, k_pe = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    c_kv = rms_norm(c_kv, p["ckv_norm"].astype(x.dtype), cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_pe  # [B,S,r], [B,S,dr]


def mla_attention(p, cfg: LMConfig, x, positions, *, blockwise: bool = False):
    """Train/prefill path (decompressed).  x: [B, S, d] -> ([B, S, d], (c_kv, k_pe))."""
    m = cfg.mla
    b, s, _ = x.shape
    qn, qr = _project_q(p, cfg, x, positions)
    c_kv, k_pe = _project_ckv(p, cfg, x, positions)
    kv = linear(p["wukv"], c_kv).reshape(b, s, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim)
    kn, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(k_pe[:, :, None, :], qr.shape)], axis=-1)
    q = jnp.concatenate([qn, qr], axis=-1)
    # v head dim may differ from qk head dim: pad v for the shared kernels.
    dq = q.shape[-1]
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dq - m.v_head_dim)))
    fn = blockwise_attention if blockwise else full_attention
    out = fn(q, k, vp, causal=True)[..., : m.v_head_dim]
    y = linear(p["wo"], out.reshape(b, s, -1))
    return y, (c_kv, k_pe)


def mla_decode(p, cfg: LMConfig, x1, ckv_cache, kpe_cache, lengths, *, paged=None):
    """Absorbed one-token decode.  x1: [B, 1, d]; caches: [B, S_max, r]/[B, S_max, dr].

    Returns (y [B,1,d], updated ckv_cache, updated kpe_cache).

    ``paged``: optional ``(tables, block_size)`` when the caches are paged
    pools ``[num_blocks, block_size, r]`` — the new latent is written at its
    (physical block, offset) and attention runs over the block-table gathered
    view; the returned caches stay in pool layout.
    """
    m = cfg.mla
    b = x1.shape[0]
    pos = lengths[:, None]  # [B,1] absolute position of the new token
    qn, qr = _project_q(p, cfg, x1, pos)
    c_new, kpe_new = _project_ckv(p, cfg, x1, pos)
    if paged is None:
        ckv_upd = ckv_cache.at[jnp.arange(b), lengths].set(c_new[:, 0])
        kpe_upd = kpe_cache.at[jnp.arange(b), lengths].set(kpe_new[:, 0])
        ckv, kpe = ckv_upd, kpe_upd
    else:
        tables, bs = paged
        phys = tables[jnp.arange(b), lengths // bs]
        off = lengths % bs
        ckv_upd = ckv_cache.at[phys, off].set(c_new[:, 0])
        kpe_upd = kpe_cache.at[phys, off].set(kpe_new[:, 0])
        # per-lane gathered view [B, max_blocks*bs, r]; positions past
        # lengths are masked below, so stale block tails cannot contribute
        ckv = ckv_upd[tables].reshape(b, -1, ckv_upd.shape[-1])
        kpe = kpe_upd[tables].reshape(b, -1, kpe_upd.shape[-1])

    # Absorb W_uk: q_lat[h] = W_uk[h]^T q_nope[h]  -> score against c_kv directly.
    wukv = p["wukv"]["w"].reshape(m.kv_lora_rank, cfg.n_heads, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wukv[..., : m.qk_nope_head_dim]  # [r, H, dn]
    w_uv = wukv[..., m.qk_nope_head_dim :]  # [r, H, dv]
    q_lat = jnp.einsum("bqhd,rhd->bqhr", qn, w_uk.astype(x1.dtype))  # [B,1,H,r]
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    scores = (jnp.einsum("bqhr,bkr->bhqk", q_lat.astype(jnp.float32), ckv.astype(jnp.float32))
              + jnp.einsum("bqhd,bkd->bhqk", qr.astype(jnp.float32), kpe.astype(jnp.float32))
              ) * scale
    kpos = jnp.arange(ckv.shape[1])[None, None, None, :]
    mask = kpos <= lengths[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkr->bqhr", probs.astype(ckv.dtype), ckv)
    v = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv.astype(x1.dtype))
    y = linear(p["wo"], v.reshape(b, 1, -1))
    return y, ckv_upd, kpe_upd
