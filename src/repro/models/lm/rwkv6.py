"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

Per head (size hs) the wkv recurrence over tokens t is

    out_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ)
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ

with w_t = exp(-exp(w0 + lora_w(x̄_t))) the *data-dependent* per-channel decay
(the Finch novelty), and token-shift interpolation x̄ = lerp(x_t, x_{t-1}, μ+lora).
Attention-free: state is [H, hs, hs] per sequence — constant in context length,
which is why rwkv6 runs the long_500k cell.  The sequential scan is the target
of the ``linear_scan`` Pallas kernel (chunked form).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm.config import LMConfig
from repro.models.lm.layers import init_linear, linear, rms_norm

_LORA_R = 32


def _lora_init(rng, d, out, dtype):
    k1, k2 = jax.random.split(rng)
    return {
        "a": (jax.random.normal(k1, (d, _LORA_R), jnp.float32) * 0.01).astype(dtype),
        "b": (jax.random.normal(k2, (_LORA_R, out), jnp.float32) * 0.01).astype(dtype),
    }


def _lora(p, x):
    return jnp.tanh(x @ p["a"].astype(x.dtype)) @ p["b"].astype(x.dtype)


def init_rwkv_block(rng, cfg: LMConfig, dtype=jnp.float32):
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    n_h = d // hs
    ks = jax.random.split(rng, 12)
    p = {
        "mu": {n: (jax.random.uniform(ks[0], (d,)) * 0.5 + 0.25).astype(dtype)
               for n in ("r", "k", "v", "g", "w")},
        "lora_mix": _lora_init(ks[1], d, d, dtype),  # shared data-dep shift mix
        "wr": init_linear(ks[2], d, d, dtype=dtype),
        "wk": init_linear(ks[3], d, d, dtype=dtype),
        "wv": init_linear(ks[4], d, d, dtype=dtype),
        "wg": init_linear(ks[5], d, d, dtype=dtype),
        "w0": (jnp.zeros((d,)) - 0.6).astype(jnp.float32),
        "lora_w": _lora_init(ks[6], d, d, dtype),
        "u": (jax.random.normal(ks[7], (n_h, hs), jnp.float32) * 0.1).astype(jnp.float32),
        "ln_x": jnp.ones((d,), dtype),  # per-head group norm gain
        "wo": init_linear(ks[8], d, d, dtype=dtype),
        # channel mix
        "cm_mu_k": (jax.random.uniform(ks[9], (d,)) * 0.5 + 0.25).astype(dtype),
        "cm_mu_r": (jax.random.uniform(ks[9], (d,)) * 0.5 + 0.25).astype(dtype),
        "cm_k": init_linear(ks[10], d, cfg.d_ff, dtype=dtype),
        "cm_v": init_linear(ks[11], cfg.d_ff, d, dtype=dtype),
        "cm_r": init_linear(ks[6], d, d, dtype=dtype),
    }
    return p


def _shift(x, last=None):
    """Token shift: x_{t-1} (zeros / carried state at t=0).  x: [B, S, d]."""
    prev = jnp.zeros_like(x[:, :1]) if last is None else last[:, None]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """r/k/v: [B, S, H, hs], w: [B, S, H, hs] decay in (0,1), u: [H, hs].
    s0: [B, H, hs, hs].  Returns (out [B, S, H, hs], s_last)."""

    def step(s, inp):
        rt, kt, vt, wt = inp  # [B, H, hs]
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,hs,hs]
        out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, out

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    s_last, out = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(out, 0, 1), s_last


def time_mix(p, cfg: LMConfig, x, *, cache=None):
    """x: [B, S, d] -> (y, new_cache {shift [B,d], state [B,H,hs,hs]})."""
    b, s, d = x.shape
    hs = cfg.rwkv_head_size
    n_h = d // hs
    last = None if cache is None else cache["shift"]
    xs = _shift(x, last)
    mix = _lora(p["lora_mix"], x)

    def lerp(name):
        mu = p["mu"][name].astype(x.dtype)
        return x + (xs - x) * jnp.clip(mu + mix, 0.0, 1.0)

    r = linear(p["wr"], lerp("r")).reshape(b, s, n_h, hs)
    k = linear(p["wk"], lerp("k")).reshape(b, s, n_h, hs)
    v = linear(p["wv"], lerp("v")).reshape(b, s, n_h, hs)
    g = jax.nn.silu(linear(p["wg"], lerp("g")))
    w_log = p["w0"].astype(jnp.float32) + _lora(p["lora_w"], lerp("w")).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log)).reshape(b, s, n_h, hs)  # data-dependent decay

    s0 = (jnp.zeros((b, n_h, hs, hs), jnp.float32) if cache is None
          else cache["state"].astype(jnp.float32))
    out, s_last = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), w, p["u"], s0)
    out = out.reshape(b, s, d).astype(x.dtype)
    # per-head group norm
    out = rms_norm(out.reshape(b, s, n_h, hs), 1.0, cfg.norm_eps).reshape(b, s, d)
    y = linear(p["wo"], out * p["ln_x"].astype(x.dtype) * g)
    return y, {"shift": x[:, -1], "state": s_last.astype(x.dtype)}


def channel_mix(p, cfg: LMConfig, x, *, cache=None):
    last = None if cache is None else cache["shift"]
    xs = _shift(x, last)
    mk = x + (xs - x) * p["cm_mu_k"].astype(x.dtype)
    mr = x + (xs - x) * p["cm_mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(linear(p["cm_k"], mk)))
    return jax.nn.sigmoid(linear(p["cm_r"], mr)) * linear(p["cm_v"], k), {"shift": x[:, -1]}


def init_rwkv_cache(cfg: LMConfig, batch: int, dtype) -> dict:
    hs = cfg.rwkv_head_size
    n_h = cfg.d_model // hs
    return {
        "tm": {"shift": jnp.zeros((batch, cfg.d_model), dtype),
               "state": jnp.zeros((batch, n_h, hs, hs), dtype)},
        "cm": {"shift": jnp.zeros((batch, cfg.d_model), dtype)},
    }
