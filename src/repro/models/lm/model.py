"""LM backbone assembly: stage-planned block stacks with scan-over-layers.

Layers are grouped into *stages* — maximal runs of a repeating block pattern —
so params stack over a leading ``repeats`` dim and the forward pass is a
``lax.scan`` per stage (one compiled block body per stage regardless of depth;
essential for 88-layer granite compile times and for remat policies).

Block spec = (mixer, ffn):
    mixer ∈ full | swa | mla | rec | rwkv      ffn ∈ dense | moe | rwkv
Examples: grok = ("full","moe")×64; deepseek = ("mla","dense") + ("mla","moe")×26;
recurrentgemma = [("rec","dense"),("rec","dense"),("swa","dense")]×8 + 2 rec.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.lm import rglru, rwkv6
from repro.models.lm.attention import (
    NEG_INF,
    banded_attention,
    blockwise_attention,
    decode_attention,
    full_attention,
)
from repro.models.lm.config import LMConfig
from repro.models.lm.layers import apply_rope, init_linear, init_mlp, linear, mlp, rms_norm
from repro.models.lm.mla import init_mla, mla_attention, mla_decode
from repro.models.lm.moe import init_moe, moe_ffn

BLOCKWISE_THRESHOLD = 2048  # switch to flash-style attention above this seq len


def _constrain(x, shardings, key):
    """Pin an activation's sharding (no-op off-mesh).

    GSPMD/Shardy propagation alone does NOT keep the batch dim sharded once
    FSDP param shardings pull feature dims toward 'data' (measured: 370
    GiB/device temps on qwen train_4k without these pins).  Production
    frameworks (MaxText et al.) pin activations at block boundaries for
    exactly this reason; ``shardings`` is the launcher-provided hint dict
    {"act": NamedSharding, "logits": NamedSharding}.
    """
    if shardings is None or shardings.get(key) is None:
        return x
    return jax.lax.with_sharding_constraint(x, shardings[key])


# ------------------------------------------------------------------ stage plan
@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str  # full | swa | mla | rec | rwkv
    ffn: str  # dense | moe | rwkv


def layer_specs(cfg: LMConfig) -> list[LayerSpec]:
    specs = []
    for i, kind in enumerate(cfg.block_types()):
        if kind == "rwkv":
            specs.append(LayerSpec("rwkv", "rwkv"))
            continue
        mixer = {"attn": "full"}.get(kind, kind)
        if cfg.moe is not None and i >= cfg.moe.first_k_dense:
            ffn = "moe"
        else:
            ffn = "dense"
        specs.append(LayerSpec(mixer, ffn))
    return specs


def stage_plan(cfg: LMConfig) -> list[tuple[tuple[LayerSpec, ...], int]]:
    """[(super-layer spec tuple, repeats), ...] covering all layers in order."""
    specs = layer_specs(cfg)
    if cfg.block_pattern is not None:
        period = len(cfg.block_pattern)
        n_full, rem = divmod(len(specs), period)
        plan = [(tuple(specs[:period]), n_full)]
        if rem:
            plan.append((tuple(specs[n_full * period :]), 1))
        return plan
    # group maximal runs of identical specs
    plan = []
    for spec, grp in itertools.groupby(specs):
        plan.append(((spec,), len(list(grp))))
    return plan


# ----------------------------------------------------------------------- init
def _init_attn(rng, cfg: LMConfig, dtype):
    kq, kk, kv, ko = jax.random.split(rng, 4)
    hd = cfg.hd
    return {
        "wq": init_linear(kq, cfg.d_model, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(kk, cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(kv, cfg.d_model, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ko, cfg.n_heads * hd, cfg.d_model, dtype=dtype),
    }


def _init_layer(rng, cfg: LMConfig, spec: LayerSpec, dtype):
    km, kf = jax.random.split(rng)
    p: dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    if spec.mixer in ("full", "swa"):
        p["attn"] = _init_attn(km, cfg, dtype)
    elif spec.mixer == "mla":
        p["attn"] = init_mla(km, cfg, dtype)
    elif spec.mixer == "rec":
        p["rec"] = rglru.init_rglru_block(km, cfg, dtype)
    elif spec.mixer == "rwkv":
        p["rwkv"] = rwkv6.init_rwkv_block(km, cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    p["norm2"] = jnp.ones((cfg.d_model,), dtype)
    if spec.ffn == "dense":
        d_ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.dense_d_ff is not None:
            d_ff = cfg.moe.dense_d_ff
        p["mlp"] = init_mlp(kf, cfg.d_model, d_ff, cfg.mlp, dtype=dtype)
    elif spec.ffn == "moe":
        p["moe"] = init_moe(kf, cfg.d_model, cfg.moe, cfg.d_ff, cfg.mlp, dtype=dtype)
    return p


def init(rng, cfg: LMConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(rng, len(stage_plan(cfg)) + 3)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model), jnp.float32)
                  * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if cfg.pos == "learned":
        params["pos"] = (jax.random.normal(ks[1], (cfg.max_seq_len, cfg.d_model),
                                           jnp.float32) * 0.02).astype(dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(ks[2], cfg.d_model, cfg.padded_vocab, dtype=dtype)
    stages = []
    for si, (specs, repeats) in enumerate(stage_plan(cfg)):
        layer_keys = jax.random.split(ks[3 + si], repeats)

        def init_super(k, _specs=specs):
            sub_keys = jax.random.split(k, len(_specs))
            return {f"sub{i}": _init_layer(sub_keys[i], cfg, sp, dtype)
                    for i, sp in enumerate(_specs)}

        stages.append(jax.vmap(init_super)(layer_keys))
    params["stages"] = stages
    return params


# -------------------------------------------------------------------- mixers
def _attn_mixer(p, cfg: LMConfig, spec: LayerSpec, x, positions, *, mode,
                cache=None, lengths=None, shardings=None, paged=None):
    """Returns (out, new_cache).  cache layout depends on mixer/mode.

    ``paged``: optional ``(tables, block_size)`` for decode against a paged
    pool (``init_paged_cache``) — ``tables`` is int32 ``[B, max_blocks]``
    mapping each lane's logical block index to a physical block.  Applies to
    seq-dim caches only (full-attn k/v, MLA ckv/kpe); swa rings and recurrent
    state stay per-lane.
    """
    b, s, _ = x.shape
    hd = cfg.hd
    window = cfg.window if spec.mixer == "swa" else None

    if spec.mixer == "mla":
        if mode == "decode":
            y, ckv, kpe = mla_decode(p["attn"], cfg, x, cache["ckv"], cache["kpe"], lengths,
                                     paged=paged)
            return y, {"ckv": ckv, "kpe": kpe}
        blockwise = s > BLOCKWISE_THRESHOLD
        y, (c_kv, k_pe) = mla_attention(p["attn"], cfg, x, positions, blockwise=blockwise)
        if mode == "prefill":
            ckv_w = _constrain(c_kv.astype(cache["ckv"].dtype), shardings, "ckv")
            kpe_w = _constrain(k_pe.astype(cache["kpe"].dtype), shardings, "ckv")
            new = {"ckv": cache["ckv"].at[:, :s].set(ckv_w),
                   "kpe": cache["kpe"].at[:, :s].set(kpe_w)}
            return y, new
        return y, None

    a = p["attn"]
    q = linear(a["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = linear(a["wk"], x).reshape(b, s, cfg.n_kv_heads, hd)
    v = linear(a["wv"], x).reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.pos == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if mode == "prefill":
        # compute-path q/k/v stay batch-sharded; without this the S-sharded
        # cache write (kv hint) back-propagates onto q and the chunked
        # attention all-gathers the whole q stack EVERY chunk iteration
        # (measured 3.2 TiB/device on prefill_32k).  Train mode needs no pin
        # (no cache write) and pinning there pessimizes the backward loop.
        q = _constrain(q, shardings, "qkv")
        k = _constrain(k, shardings, "qkv")
        v = _constrain(v, shardings, "qkv")

    if mode == "decode":
        if window is not None:  # ring buffer of size window
            slot = lengths % window
            kc = cache["k"].at[jnp.arange(b), slot].set(k[:, 0])
            vc = cache["v"].at[jnp.arange(b), slot].set(v[:, 0])
            n_valid = jnp.minimum(lengths + 1, window)
            out = _ring_decode(q, kc, vc, n_valid)
            new_cache = {"k": kc, "v": vc}
        elif paged is not None:
            # paged pool: write the token's k/v at (physical block, offset),
            # then attend against the block-table gathered view.  The gather
            # happens HERE, per layer inside the scan body, so the transient
            # is one layer's [B, max_blocks*bs] view — never the whole pool.
            tables, bs = paged
            phys = tables[jnp.arange(b), lengths // bs]
            off = lengths % bs
            kc = cache["k"].at[phys, off].set(k[:, 0])
            vc = cache["v"].at[phys, off].set(v[:, 0])
            kv = kc[tables].reshape(b, -1, cfg.n_kv_heads, hd)
            vv = vc[tables].reshape(b, -1, cfg.n_kv_heads, hd)
            # positions >= lengths+1 (unwritten block tails, null-block rows
            # of dead lanes) hold stale-but-finite garbage; the mask zeroes
            # them exactly, so the view is bit-equivalent to the contiguous
            # cache whenever max_blocks*bs == max_len
            out = decode_attention(q, kv, vv, lengths + 1)
            new_cache = {"k": kc, "v": vc}
        else:
            kc = cache["k"].at[jnp.arange(b), lengths].set(k[:, 0])
            vc = cache["v"].at[jnp.arange(b), lengths].set(v[:, 0])
            out = decode_attention(q, kc, vc, lengths + 1)
            new_cache = {"k": kc, "v": vc}
        return linear(a["wo"], out.reshape(b, 1, -1)), new_cache

    # train / prefill
    if window is not None and s > 2 * window:
        out = banded_attention(q, k, v, window=window,
                               q_chunk=min(cfg.q_chunk, window))
    elif s > BLOCKWISE_THRESHOLD:
        out = blockwise_attention(q, k, v, causal=True, window=window,
                                  q_chunk=min(cfg.q_chunk, s),
                                  kv_chunk=min(cfg.kv_chunk, s))
    else:
        out = full_attention(q, k, v, causal=True, window=window)
    y = linear(a["wo"], out.reshape(b, s, -1))

    new_cache = None
    if mode == "prefill":
        if window is not None:
            w = window
            tail = min(s, w)
            slots = (positions[:, -tail:]) % w  # [B, tail]
            kc = jnp.zeros_like(cache["k"]).at[jnp.arange(b)[:, None], slots].set(k[:, -tail:])
            vc = jnp.zeros_like(cache["v"]).at[jnp.arange(b)[:, None], slots].set(v[:, -tail:])
            new_cache = {"k": kc, "v": vc}
        else:
            # pin the written k/v to the cache's own sharding BEFORE the
            # update: the reshard is then a local slice instead of a
            # full-tensor involuntary rematerialization per layer
            kw = _constrain(k.astype(cache["k"].dtype), shardings, "kv")
            vw = _constrain(v.astype(cache["v"].dtype), shardings, "kv")
            new_cache = {"k": cache["k"].at[:, :s].set(kw),
                         "v": cache["v"].at[:, :s].set(vw)}
    return y, new_cache


def _ring_decode(q1, k_ring, v_ring, n_valid):
    """Decode against a ring buffer: all slots < n_valid (per batch) are live;
    slot order is irrelevant to attention."""
    b = q1.shape[0]
    kpos = jnp.arange(k_ring.shape[1])[None, :]
    mask = kpos < n_valid[:, None]
    # reuse decode_attention by passing per-batch "length" = window validity
    return decode_attention(q1, jnp.where(mask[..., None, None], k_ring, 0),
                            v_ring, n_valid)


# --------------------------------------------------------------------- layers
def _layer_apply(p, cfg: LMConfig, spec: LayerSpec, x, positions, *, mode,
                 cache=None, lengths=None, shardings=None, paged=None):
    """One block.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"].astype(x.dtype), cfg.norm_eps)

    if spec.mixer == "rec":
        out, new_mix_cache = rglru.rglru_block(p["rec"], cfg, h,
                                               cache=None if mode == "train" else cache)
    elif spec.mixer == "rwkv":
        out, new_mix_cache = rwkv6.time_mix(
            p["rwkv"], cfg, h, cache=None if mode == "train" else cache and cache["tm"])
    else:
        out, new_mix_cache = _attn_mixer(p, cfg, spec, h, positions, mode=mode,
                                         cache=cache, lengths=lengths,
                                         shardings=shardings, paged=paged)
    x = x + out

    if spec.ffn == "rwkv":
        h2 = rms_norm(x, p["norm2"].astype(x.dtype), cfg.norm_eps)
        out2, new_cm_cache = rwkv6.channel_mix(
            p["rwkv"], cfg, h2, cache=None if mode == "train" else cache and cache["cm"])
        x = x + out2
        new_cache = None if mode == "train" else {"tm": new_mix_cache, "cm": new_cm_cache}
        return x, new_cache, aux

    h2 = rms_norm(x, p["norm2"].astype(x.dtype), cfg.norm_eps)
    if spec.ffn == "moe":
        groups = (shardings or {}).get("moe_groups", 1)
        out2, aux = moe_ffn(p["moe"], h2, cfg.moe, cfg.mlp, shardings=shardings,
                            groups=groups)
    else:
        out2 = mlp(p["mlp"], h2, cfg.mlp)
    x = x + out2
    return x, new_mix_cache, aux


def _run_stages(params, cfg: LMConfig, x, positions, *, mode, caches=None,
                lengths=None, remat=False, shardings=None, paged=None):
    """Scan over each stage's repeats.  Returns (x, new_caches, aux_total)."""
    plan = stage_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for (specs, repeats), stage_p, stage_c in zip(
            plan, params["stages"], caches or [None] * len(plan)):

        def body(carry, layer_in):
            xx, aux_acc = carry
            lp, lc = layer_in
            out_caches = {}
            for i, sp in enumerate(specs):
                sub_c = None if lc is None else lc[f"sub{i}"]
                xx, nc, aux = _layer_apply(lp[f"sub{i}"], cfg, sp, xx, positions,
                                           mode=mode, cache=sub_c, lengths=lengths,
                                           shardings=shardings, paged=paged)
                xx = _constrain(xx, shardings, "act")
                out_caches[f"sub{i}"] = nc
                aux_acc = aux_acc + aux
            return (xx, aux_acc), out_caches

        if remat:
            body = jax.checkpoint(body)
        if stage_c is None:
            (x, aux_total), scanned = jax.lax.scan(
                lambda c, lp: body(c, (lp, None)), (x, aux_total), stage_p)
            new_caches.append(scanned if mode != "train" else None)
        else:
            (x, aux_total), scanned = jax.lax.scan(
                body, (x, aux_total), (stage_p, stage_c))
            new_caches.append(scanned)
    return x, new_caches, aux_total


# ----------------------------------------------------------------- public API
def embed_tokens(params, cfg: LMConfig, tokens, *, prefix_embeds=None,
                 pos_offset=None):
    """tokens: [B, S] int32 -> (x [B, S(+P), d] in compute dtype, positions)."""
    cdtype = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens].astype(cdtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cdtype), x], axis=1)
    b, s, _ = x.shape
    if pos_offset is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    else:
        positions = pos_offset[:, None] + jnp.arange(s)[None]
    if cfg.pos == "learned":
        x = x + params["pos"][positions].astype(cdtype)
    return x, positions


def logits_fn(params, cfg: LMConfig, x):
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]["w"])
    logits = x @ w.astype(x.dtype)
    if cfg.padded_vocab != cfg.vocab:
        # mask padding columns so softmax/argmax never see them
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad_mask, jnp.asarray(NEG_INF, logits.dtype), logits)
    return logits


def forward(params, cfg: LMConfig, tokens, *, prefix_embeds=None, remat=False,
            shardings=None):
    """Training forward.  Returns (logits [B, S, V], aux_loss)."""
    x, positions = embed_tokens(params, cfg, tokens, prefix_embeds=prefix_embeds)
    x = _constrain(x, shardings, "act")
    x, _, aux = _run_stages(params, cfg, x, positions, mode="train", remat=remat,
                            shardings=shardings)
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = _constrain(logits_fn(params, cfg, x), shardings, "logits")
    return logits, aux


def backbone(params, cfg: LMConfig, x_embeds, *, remat=False, shardings=None):
    """Run the block stack on precomputed embeddings (ST-LLM / modality
    frontends).  x_embeds: [B, S, d] -> (hidden [B, S, d], aux)."""
    b, s, _ = x_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = _constrain(x_embeds.astype(jnp.dtype(cfg.dtype)), shardings, "act")
    x, _, aux = _run_stages(params, cfg, x, positions, mode="train", remat=remat,
                            shardings=shardings)
    return rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps), aux


def loss_fn(params, cfg: LMConfig, tokens_in, labels, *, prefix_embeds=None,
            remat=False, shardings=None):
    """Next-token cross-entropy (+ MoE aux).  labels: [B, S] (-1 = ignore)."""
    logits, aux = forward(params, cfg, tokens_in, prefix_embeds=prefix_embeds,
                          remat=remat, shardings=shardings)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1] :]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    # one-hot-select instead of take_along_axis: stays sharded over a
    # vocab-partitioned logits axis (gather along a sharded dim would
    # all-gather the full [B,S,V] f32 logits)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=jnp.int32)
    hit = labels[..., None] == vocab_iota
    gold = jnp.sum(jnp.where(hit, logits.astype(jnp.float32), 0.0), axis=-1)
    valid = labels >= 0
    nll = jnp.where(valid, lse - gold, 0.0)
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)
    return loss + aux, {"nll": loss, "aux": aux}


# -------------------------------------------------------------------- serving
def init_cache(cfg: LMConfig, batch: int, max_len: int):
    """Cache pytree mirroring the stage plan (stacked over repeats)."""
    cdtype = jnp.dtype(cfg.dtype)
    hd = cfg.hd

    def one_layer(spec: LayerSpec):
        c: dict[str, Any] = {}
        if spec.mixer in ("full",):
            c = {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), cdtype),
                 "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), cdtype)}
        elif spec.mixer == "swa":
            w = min(cfg.window, max_len)
            c = {"k": jnp.zeros((batch, w, cfg.n_kv_heads, hd), cdtype),
                 "v": jnp.zeros((batch, w, cfg.n_kv_heads, hd), cdtype)}
        elif spec.mixer == "mla":
            m = cfg.mla
            c = {"ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), cdtype),
                 "kpe": jnp.zeros((batch, max_len, m.qk_rope_head_dim), cdtype)}
        elif spec.mixer == "rec":
            c = rglru.init_rglru_cache(cfg, batch, cdtype)
        elif spec.mixer == "rwkv":
            c = rwkv6.init_rwkv_cache(cfg, batch, cdtype)
        return c

    caches = []
    for specs, repeats in stage_plan(cfg):
        layer = {f"sub{i}": one_layer(sp) for i, sp in enumerate(specs)}
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (repeats,) + a.shape), layer))
    return caches


def scatter_cache(cache, sub, slots):
    """Scatter a k-batch cache pytree into k (arbitrary, non-contiguous)
    lanes of a pool cache.

    ``cache``: the slot-pool cache from ``init_cache`` — every leaf is
    stage-stacked ``[repeats, batch, ...]`` with batch at axis 1.  ``sub``:
    the same pytree with batch ``k`` (a batched-prefill output).  ``slots``:
    int32 ``[k]`` lane indices.  One fused scatter per leaf replaces the
    per-request ``dynamic_update_slice`` chain the single-lane fill path
    pays k times.
    """
    slots = jnp.asarray(slots, jnp.int32)

    def put(big, small):
        return big.at[:, slots].set(small.astype(big.dtype))

    return jax.tree.map(put, cache, sub)


# ------------------------------------------------------------ paged KV-cache
def init_paged_cache(cfg: LMConfig, batch: int, max_len: int, *,
                     num_blocks: int, block_size: int):
    """Paged cache pool: seq-dim leaves become shared block pools.

    Full-attn k/v and MLA ckv/kpe leaves are ``[repeats, num_blocks,
    block_size, ...]`` — one pool per layer, shared by every lane through
    per-lane block tables (``serve.blocks.BlockPool`` owns the allocation;
    physical block 0 is the null block).  Per-lane state with no paged seq
    dim (swa rings, RG-LRU / RWKV recurrent state) keeps the ``init_cache``
    layout ``[repeats, batch, ...]``.
    """
    cdtype = jnp.dtype(cfg.dtype)
    hd = cfg.hd

    def one_layer(spec: LayerSpec):
        if spec.mixer == "full":
            return {"k": jnp.zeros((num_blocks, block_size, cfg.n_kv_heads, hd), cdtype),
                    "v": jnp.zeros((num_blocks, block_size, cfg.n_kv_heads, hd), cdtype)}
        if spec.mixer == "swa":
            w = min(cfg.window, max_len)
            return {"k": jnp.zeros((batch, w, cfg.n_kv_heads, hd), cdtype),
                    "v": jnp.zeros((batch, w, cfg.n_kv_heads, hd), cdtype)}
        if spec.mixer == "mla":
            m = cfg.mla
            return {"ckv": jnp.zeros((num_blocks, block_size, m.kv_lora_rank), cdtype),
                    "kpe": jnp.zeros((num_blocks, block_size, m.qk_rope_head_dim), cdtype)}
        if spec.mixer == "rec":
            return rglru.init_rglru_cache(cfg, batch, cdtype)
        if spec.mixer == "rwkv":
            return rwkv6.init_rwkv_cache(cfg, batch, cdtype)
        return {}

    caches = []
    for specs, repeats in stage_plan(cfg):
        layer = {f"sub{i}": one_layer(sp) for i, sp in enumerate(specs)}
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (repeats,) + a.shape), layer))
    return caches


def paged_cache_mask(cfg: LMConfig):
    """Bool pytree congruent with the cache: True at paged (seq-dim) leaves.

    Decided per layer SPEC, not by shape — a swa ring whose window happens to
    equal ``max_len`` must still take the ring decode path, not the paged one.
    """
    def one_layer(spec: LayerSpec):
        if spec.mixer == "full":
            return {"k": True, "v": True}
        if spec.mixer == "swa":
            return {"k": False, "v": False}
        if spec.mixer == "mla":
            return {"ckv": True, "kpe": True}
        if spec.mixer == "rec":
            shapes = jax.eval_shape(lambda: rglru.init_rglru_cache(cfg, 1, jnp.float32))
            return jax.tree.map(lambda _: False, shapes)
        if spec.mixer == "rwkv":
            shapes = jax.eval_shape(lambda: rwkv6.init_rwkv_cache(cfg, 1, jnp.float32))
            return jax.tree.map(lambda _: False, shapes)
        return {}

    return [{f"sub{i}": one_layer(sp) for i, sp in enumerate(specs)}
            for specs, _ in stage_plan(cfg)]


def scatter_cache_paged(cache, sub, slots, phys, *, block_size: int, mask):
    """Land a k-batch contiguous prefill cache into a paged pool.

    ``cache``: pool from ``init_paged_cache``.  ``sub``: a contiguous
    prefill-output cache with batch k (seq dim = the sub cache's line
    length).  ``slots``: int32 ``[k]`` lane ids, used for the per-lane
    (unpaged) leaves exactly like ``scatter_cache``.  ``phys``: int32
    ``[k, nb]`` physical block ids covering logical positions
    ``0..nb*block_size`` of each lane — the prompt's blocks.  ``mask``:
    ``paged_cache_mask(cfg)``.

    Paged leaves reshape the sub line into ``nb`` blocks and scatter them to
    their physical rows in one fused update; positions past the prompt inside
    the last block are zero-filled (masked by lane lengths until decode
    overwrites them).
    """
    slots = jnp.asarray(slots, jnp.int32)
    phys = jnp.asarray(phys, jnp.int32)
    nb = phys.shape[1]

    def put(is_paged, big, small):
        small = small.astype(big.dtype)
        if not is_paged:
            return big.at[:, slots].set(small)
        r, k, s = small.shape[:3]
        want = nb * block_size
        if s > want:
            small = small[:, :, :want]
        elif s < want:
            widths = [(0, 0), (0, 0), (0, want - s)] + [(0, 0)] * (small.ndim - 3)
            small = jnp.pad(small, widths)
        small = small.reshape((r, k, nb, block_size) + small.shape[3:])
        return big.at[:, phys].set(small)

    return jax.tree.map(put, mask, cache, sub)


def prefill(params, cfg: LMConfig, tokens, cache, *, prefix_embeds=None,
            shardings=None):
    """Fill the cache from a prompt.  Returns (last-token logits, cache, lengths)."""
    x, positions = embed_tokens(params, cfg, tokens, prefix_embeds=prefix_embeds)
    x = _constrain(x, shardings, "act")
    x, new_caches, _ = _run_stages(params, cfg, x, positions, mode="prefill",
                                   caches=cache, shardings=shardings)
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    logits = logits_fn(params, cfg, x[:, -1:])[:, 0]
    lengths = jnp.full((tokens.shape[0],), x.shape[1], jnp.int32)
    return logits, new_caches, lengths


def decode_step(params, cfg: LMConfig, token, cache, lengths, *, shardings=None,
                paged=None):
    """One decode step.  token: [B, 1] -> (logits [B, V], new cache).

    ``paged``: optional ``(tables, block_size)`` when ``cache`` is a paged
    pool from ``init_paged_cache`` — tables map each lane's logical blocks to
    physical pool blocks; per-layer writes/gathers go through them inside the
    stage scan (see ``_attn_mixer``).
    """
    x, positions = embed_tokens(params, cfg, token, pos_offset=lengths)
    x = _constrain(x, shardings, "act")
    x, new_caches, _ = _run_stages(params, cfg, x, positions, mode="decode",
                                   caches=cache, lengths=lengths,
                                   shardings=shardings, paged=paged)
    x = rms_norm(x, params["final_norm"].astype(x.dtype), cfg.norm_eps)
    return logits_fn(params, cfg, x[:, 0]), new_caches
