"""ST-LLM-style model (Liu et al. 2024) — the paper's §5.5 scaling-study model.

Spatial-temporal tokenisation: each graph node's input window [T', F] becomes
one token via a linear patch embedding, plus learned spatial (per-node) and
time-of-day embeddings; the token sequence (length N) runs through the LM
backbone (GPT2-style here, built from ``repro.models.lm``); a regression head
maps each node token to its horizon forecast.  Index-batching applies
unchanged: the model consumes the same sequence-to-sequence windows.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.lm import model as lm
from repro.models.lm.config import LMConfig


@dataclasses.dataclass(frozen=True)
class STLLMConfig:
    num_nodes: int
    in_features: int = 2
    out_features: int = 1
    input_len: int = 12
    horizon: int = 12
    d_model: int = 256
    layers: int = 6
    n_heads: int = 8
    d_ff: int = 1024
    steps_per_day: int = 288
    dtype: str = "float32"

    def backbone_config(self) -> LMConfig:
        return LMConfig(
            name="stllm-backbone", layers=self.layers, d_model=self.d_model,
            n_heads=self.n_heads, n_kv_heads=self.n_heads, d_ff=self.d_ff,
            vocab=1, attn="full", pos="none", mlp="gelu",
            dtype=self.dtype, param_dtype="float32",
        )


def init(rng, cfg: STLLMConfig) -> dict[str, Any]:
    kp, ks, kt, kb, kh = jax.random.split(rng, 5)
    in_dim = cfg.input_len * cfg.in_features
    return {
        "patch": {"w": jax.random.normal(kp, (in_dim, cfg.d_model), jnp.float32)
                  / jnp.sqrt(in_dim), "b": jnp.zeros((cfg.d_model,))},
        "spatial": jax.random.normal(ks, (cfg.num_nodes, cfg.d_model), jnp.float32) * 0.02,
        "tod": jax.random.normal(kt, (cfg.steps_per_day, cfg.d_model), jnp.float32) * 0.02,
        "backbone": lm.init(kb, cfg.backbone_config()),
        "head": {"w": jax.random.normal(kh, (cfg.d_model, cfg.horizon * cfg.out_features),
                                        jnp.float32) / jnp.sqrt(cfg.d_model),
                 "b": jnp.zeros((cfg.horizon * cfg.out_features,))},
    }


def apply(params, cfg: STLLMConfig, x_seq: jnp.ndarray, *, tod_index=None) -> jnp.ndarray:
    """x_seq: [B, T', N, F] -> [B, horizon, N, out_features]."""
    b, t, n, f = x_seq.shape
    tokens = jnp.transpose(x_seq, (0, 2, 1, 3)).reshape(b, n, t * f)
    x = tokens @ params["patch"]["w"].astype(tokens.dtype) + params["patch"]["b"]
    x = x + params["spatial"][None].astype(x.dtype)
    if tod_index is not None:  # [B] time-of-day bucket of the window start
        x = x + params["tod"][tod_index][:, None].astype(x.dtype)
    h, _ = lm.backbone(params["backbone"], cfg.backbone_config(), x)
    out = h.astype(jnp.float32) @ params["head"]["w"] + params["head"]["b"]
    out = out.reshape(b, n, cfg.horizon, cfg.out_features)
    return jnp.transpose(out, (0, 2, 1, 3))


@partial(jax.jit, static_argnames=("cfg",))
def loss_fn(params, cfg: STLLMConfig, x, y):
    pred = apply(params, cfg, x)
    return jnp.mean(jnp.abs(pred - y[..., : cfg.out_features]))
