"""Sensor-graph construction (paper §2.1).

DCRNN-style weighted adjacency from sensor coordinates: Gaussian kernel of
pairwise road distance, thresholded for sparsity, plus the dual random-walk
transition matrices used by diffusion convolution (forward D_O^{-1} A and
reverse D_I^{-1} A^T).
"""
from __future__ import annotations

import numpy as np


def random_sensor_coords(nodes: int, *, seed: int = 0) -> np.ndarray:
    """Plausible sensor layout: clusters along a few 'highways'."""
    rng = np.random.default_rng(seed)
    n_roads = max(1, nodes // 64)
    coords = []
    for r in range(n_roads):
        start = rng.uniform(0, 100, size=2)
        direction = rng.standard_normal(2)
        direction /= np.linalg.norm(direction)
        n = nodes // n_roads + (1 if r < nodes % n_roads else 0)
        ts = np.sort(rng.uniform(0, 60, size=n))
        pts = start[None, :] + ts[:, None] * direction[None, :]
        pts += rng.standard_normal((n, 2)) * 0.5
        coords.append(pts)
    return np.concatenate(coords, axis=0)[:nodes]


def gaussian_adjacency(
    coords: np.ndarray, *, threshold: float = 0.1, sigma: float | None = None
) -> np.ndarray:
    """W_ij = exp(-d_ij^2 / sigma^2), zeroed below ``threshold`` (DCRNN eq. 10)."""
    d = np.linalg.norm(coords[:, None, :] - coords[None, :, :], axis=-1)
    if sigma is None:
        sigma = float(d.std()) or 1.0
    w = np.exp(-((d / sigma) ** 2))
    w[w < threshold] = 0.0
    np.fill_diagonal(w, 1.0)
    return w.astype(np.float32)


def transition_matrices(adj: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(D_O^{-1} A, D_I^{-1} A^T) — forward/reverse random-walk operators."""
    out_deg = adj.sum(axis=1, keepdims=True)
    in_deg = adj.sum(axis=0, keepdims=True)
    fwd = adj / np.maximum(out_deg, 1e-8)
    rev = adj.T / np.maximum(in_deg.T, 1e-8)
    return fwd.astype(np.float32), rev.astype(np.float32)


def sym_norm_adjacency(adj: np.ndarray) -> np.ndarray:
    """D^{-1/2} (A + I) D^{-1/2} — GCN operator used by A3T-GCN / T-GCN."""
    a = adj + np.eye(adj.shape[0], dtype=adj.dtype)
    d = a.sum(axis=1)
    d_inv_sqrt = 1.0 / np.sqrt(np.maximum(d, 1e-8))
    return (a * d_inv_sqrt[:, None] * d_inv_sqrt[None, :]).astype(np.float32)
