"""Dataset specs from the paper's Table 1.

Sizes before/after preprocessing are reproduced analytically by
``benchmarks/table1_memory.py`` from these specs + the window math in
``repro.core.windows``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    kind: str  # epidemiological | energy | traffic
    features: int
    nodes: int
    entries: int
    horizon: int  # windows used by the paper's pipelines
    raw_bytes: int  # "Size Before Preprocessing" (Table 1)
    table1_post_bytes: float | None = None  # paper-reported post size, bytes


_KB, _MB, _GB = 1e3, 1e6, 2**30  # Table 1 mixes decimal KB/MB with GiB; see DESIGN.md §7

TABLE1 = {
    "chickenpox-hungary": DatasetSpec(
        "chickenpox-hungary", "epidemiological", 1, 20, 522, 4,
        raw_bytes=int(83.36 * _KB), table1_post_bytes=657.92 * _KB,
    ),
    "windmill-large": DatasetSpec(
        "windmill-large", "energy", 1, 319, 17_472, 8,
        raw_bytes=int(44.59 * _MB), table1_post_bytes=712.80 * _MB,
    ),
    "metr-la": DatasetSpec(
        "metr-la", "traffic", 2, 207, 34_272, 12,
        raw_bytes=int(54.39 * _MB), table1_post_bytes=2.54 * _GB,
    ),
    "pems-bay": DatasetSpec(
        "pems-bay", "traffic", 2, 325, 52_105, 12,
        raw_bytes=int(129.62 * _MB), table1_post_bytes=6.05 * _GB,
    ),
    "pems-all-la": DatasetSpec(
        "pems-all-la", "traffic", 2, 2_716, 105_120, 12,
        raw_bytes=int(2.12 * _GB), table1_post_bytes=102.08 * _GB,
    ),
    "pems": DatasetSpec(
        "pems", "traffic", 2, 11_160, 105_120, 12,
        raw_bytes=int(8.71 * _GB), table1_post_bytes=419.46 * _GB,
    ),
}


def get_dataset_spec(name: str) -> DatasetSpec:
    try:
        return TABLE1[name]
    except KeyError:
        raise KeyError(f"unknown dataset {name!r}; known: {sorted(TABLE1)}") from None
