"""Train-split standardisation (paper Alg. 1 lines 16-20).

The paper computes mean/std over the *training windows* of x.  Because every
training window is a contiguous view into the series, this equals the mean/std
over the series range the training windows cover (up to the triangular
under-weighting of the first/last ``horizon − 1`` steps, which is O(h/T) and
irrelevant at PeMS scale).  We standardise over the covered range — this is
what makes index-batching possible: normalisation happens **in place on the
single series copy**, never on materialised snapshots.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Scaler:
    mean: float
    std: float

    def transform(self, x):
        return (x - self.mean) / self.std

    def inverse(self, x):
        return x * self.std + self.mean


def fit_scaler(series: np.ndarray, train_end_step: int, feature: int | None = 0) -> Scaler:
    """Fit on ``series[:train_end_step]``.

    ``feature``: traffic pipelines standardise the signal channel only (speed),
    leaving encoded time-of-day channels alone; pass ``None`` to fit over all
    channels (paper Alg. 1 behaviour).
    """
    sl = series[:train_end_step] if feature is None else series[:train_end_step, ..., feature]
    mean = float(np.mean(sl))
    std = float(np.std(sl))
    if std == 0.0:
        std = 1.0
    return Scaler(mean=mean, std=std)


def apply_scaler(series: np.ndarray, scaler: Scaler, feature: int | None = 0) -> np.ndarray:
    out = np.array(series, copy=True)
    if feature is None:
        out = (out - scaler.mean) / scaler.std
    else:
        out[..., feature] = (out[..., feature] - scaler.mean) / scaler.std
    return out


def apply_scaler_device(series: jnp.ndarray, scaler: Scaler, feature: int | None = 0):
    """On-device standardisation — the GPU-index-batching path (§4.1):
    the raw series is transferred once and standardised on the accelerator."""
    if feature is None:
        return (series - scaler.mean) / scaler.std
    col = (series[..., feature] - scaler.mean) / scaler.std
    return series.at[..., feature].set(col)
