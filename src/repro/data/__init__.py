from repro.data.adjacency import (
    gaussian_adjacency,
    random_sensor_coords,
    sym_norm_adjacency,
    transition_matrices,
)
from repro.data.normalize import Scaler, apply_scaler, apply_scaler_device, fit_scaler
from repro.data.registry import TABLE1, DatasetSpec, get_dataset_spec
from repro.data.synthetic import make_token_stream, make_traffic_series

__all__ = [
    "Scaler",
    "fit_scaler",
    "apply_scaler",
    "apply_scaler_device",
    "TABLE1",
    "DatasetSpec",
    "get_dataset_spec",
    "make_traffic_series",
    "make_token_stream",
    "gaussian_adjacency",
    "random_sensor_coords",
    "sym_norm_adjacency",
    "transition_matrices",
]
