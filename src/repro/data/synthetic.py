"""Synthetic PeMS-shaped traffic series.

The real PeMS feed is not redistributable; for correctness/benchmark work we
generate series with the same statistical shape the paper describes (Table 1):
``[entries, nodes, features]`` with feature 0 = speed-like signal (diurnal
cycle + spatially-correlated AR noise + incident dips) and feature 1 =
time-of-day encoding — the "speed, day of week" pair of PeMS.  Spatial
correlation follows the sensor graph so that diffusion convolutions have real
signal to learn.
"""
from __future__ import annotations

import numpy as np

STEPS_PER_DAY = 288  # 5-minute bins, as PeMS


def make_traffic_series(
    entries: int,
    nodes: int,
    features: int = 2,
    *,
    seed: int = 0,
    adjacency: np.ndarray | None = None,
    dtype=np.float32,
) -> np.ndarray:
    """Return ``[entries, nodes, features]`` synthetic traffic data."""
    rng = np.random.default_rng(seed)
    t = np.arange(entries, dtype=np.float64)
    tod = (t % STEPS_PER_DAY) / STEPS_PER_DAY  # [T]

    # Per-node free-flow speed and diurnal dip depth/phase.
    free_flow = rng.uniform(55.0, 70.0, size=nodes)
    dip = rng.uniform(10.0, 30.0, size=nodes)
    phase = rng.uniform(-0.05, 0.05, size=nodes)

    # Two rush-hour dips (morning/evening) via sum of Gaussians over tod.
    def rush(center):
        return np.exp(-0.5 * ((tod[:, None] - center - phase[None, :]) / 0.06) ** 2)

    speed = free_flow[None, :] - dip[None, :] * (rush(0.33) + 0.8 * rush(0.71))

    # AR(1) noise, spatially smoothed through the adjacency if given.
    noise = rng.standard_normal((entries, nodes)) * 2.0
    for i in range(1, entries):
        noise[i] += 0.85 * noise[i - 1]
        noise[i] *= 0.55
    if adjacency is not None:
        deg = adjacency.sum(axis=1, keepdims=True) + 1e-6
        smooth = adjacency / deg
        noise = noise + noise @ smooth.T * 0.5
    speed = np.clip(speed + noise, 3.0, 85.0)

    out = np.zeros((entries, nodes, features), dtype=dtype)
    out[..., 0] = speed.astype(dtype)
    if features > 1:
        out[..., 1] = np.broadcast_to(tod[:, None], (entries, nodes)).astype(dtype)
    for f in range(2, features):
        out[..., f] = rng.standard_normal((entries, nodes)).astype(dtype)
    return out


def make_token_stream(entries: int, vocab: int, *, seed: int = 0) -> np.ndarray:
    """Synthetic LM token stream (Zipfian) — the nodes==1 degenerate series used
    to apply index-batching to the assigned LM architectures."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks
    p /= p.sum()
    return rng.choice(vocab, size=entries, p=p).astype(np.int32)
